"""Scenario API: registries, golden bit-for-bit reproduction, new policy /
arrival / fleet compositions, and loop-vs-vectorized parity for all of them.

``tests/data/scenario_golden.json`` pins the seeded results of the four
paper policies as produced by the pre-registry engines (PR 1): registry-
constructed policies must reproduce them bit-for-bit — energies, update
counts, queue means and the full push-log digest — on every engine.

(Push-log digests: PR 4's ``PushLog`` normalizes every engine's entries to
python scalars, so the loop engine's digests — which historically hashed
``np.float64`` reprs — were regenerated to the values the vectorized
engine always produced; the numeric content is unchanged and all engines
now digest identically.)
"""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.core import (BernoulliArrivals, CustomCatalogFleet,
                        DiurnalArrivals, GreedyThresholdPolicy,
                        MarkovModulatedArrivals, PaperFleet, Policy,
                        Scenario, SimConfig, SyntheticFleet, TraceArrivals,
                        FederatedSim, registered_arrivals, registered_fleets,
                        registered_policies, register_policy,
                        resolve_arrival, resolve_fleet, resolve_policy,
                        run_experiment, TESTBED)
from repro.core.simulator import POLICIES

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "scenario_golden.json")

CONFIGS = {
    "default": dict(horizon_s=2000, n_users=12, seed=2),
    "alt": dict(seed=7, app_arrival_p=0.01, horizon_s=1500, n_users=16),
}


def _digest_push_log(log):
    h = hashlib.sha256()
    for e in log:
        h.update(f'{e["t"]},{e["user"]},{e["lag"]},{e["gap"]!r},'
                 f'{int(e["corun"])};'.encode())
    return h.hexdigest()


def assert_equivalent(a, b, push_log=True):
    assert a.updates == b.updates
    assert b.energy_j == pytest.approx(a.energy_j, rel=1e-9)
    assert b.mean_Q == pytest.approx(a.mean_Q, rel=1e-9, abs=1e-12)
    assert b.mean_H == pytest.approx(a.mean_H, rel=1e-6, abs=1e-9)
    assert b.corun_fraction == pytest.approx(a.corun_fraction)
    np.testing.assert_array_equal(a.trace_t, b.trace_t)
    np.testing.assert_allclose(b.trace_energy, a.trace_energy, rtol=1e-9)
    if push_log:
        assert [(e["t"], e["user"], e["lag"], e["corun"])
                for e in a.push_log] == \
               [(e["t"], e["user"], e["lag"], e["corun"])
                for e in b.push_log]


# ---------------------------------------------------------------------------
# Golden bit-for-bit reproduction (acceptance criterion vs PR 1)
# ---------------------------------------------------------------------------
class TestGoldenParity:
    @pytest.fixture(scope="class")
    def golden(self):
        with open(GOLDEN) as f:
            return json.load(f)

    @pytest.mark.parametrize("cname", list(CONFIGS))
    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("engine", ["loop", "vectorized"])
    def test_registry_policies_reproduce_pr1(self, golden, cname, policy,
                                             engine):
        g = golden[f"{cname}/{policy}/{engine}"]
        r = run_experiment(Scenario(policy=policy, engine=engine,
                                    **CONFIGS[cname]))
        assert r.energy_j == g["energy_j"]          # bit-for-bit
        assert r.updates == g["updates"]
        assert r.mean_Q == g["mean_Q"]
        assert r.mean_H == g["mean_H"]
        assert r.corun_fraction == g["corun_fraction"]
        assert len(r.push_log) == g["n_push"]
        assert _digest_push_log(r.push_log) == g["push_log_sha256"]

    @pytest.mark.parametrize("policy", ["sync", "immediate", "online"])
    def test_jax_engine_reproduces_pr1(self, golden, policy):
        import jax
        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        try:
            g = golden[f"default/{policy}/jax"]
            r = run_experiment(Scenario(policy=policy, engine="jax",
                                        collect_push_log=False,
                                        **CONFIGS["default"]))
        finally:
            jax.config.update("jax_enable_x64", prev)
        assert r.energy_j == g["energy_j"]
        assert r.updates == g["updates"]
        assert r.mean_Q == g["mean_Q"]
        assert r.mean_H == g["mean_H"]

    def test_policy_objects_match_strings(self, golden):
        """Explicitly constructed policy instances == registry strings."""
        from repro.core import OnlinePolicy
        g = golden["default/online/vectorized"]
        r = run_experiment(Scenario(policy=OnlinePolicy(),
                                    engine="vectorized",
                                    **CONFIGS["default"]))
        assert r.energy_j == g["energy_j"] and r.updates == g["updates"]


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------
class TestRegistries:
    def test_paper_policies_registered(self):
        assert set(POLICIES) <= set(registered_policies())
        assert "greedy" in registered_policies()

    def test_arrivals_and_fleets_registered(self):
        assert {"bernoulli", "diurnal", "bursty", "trace"} <= \
            set(registered_arrivals())
        assert {"paper", "synthetic", "custom"} <= set(registered_fleets())

    def test_resolve_policy_roundtrip_singleton(self):
        a = resolve_policy("online")
        assert a is resolve_policy("online")     # jit-cache-friendly
        assert resolve_policy(a) is a            # instance passthrough
        assert a.name == "online"

    def test_resolve_unknown_raises(self):
        with pytest.raises(ValueError, match="policy"):
            resolve_policy("pilla22")
        with pytest.raises(ValueError, match="arrival"):
            resolve_arrival("lognormal")
        with pytest.raises(ValueError, match="fleet"):
            resolve_fleet("datacenter")

    def test_resolve_rejects_wrong_types(self):
        with pytest.raises(ValueError, match="policy"):
            resolve_policy(42)

    def test_custom_policy_registration(self):
        @register_policy
        class _Never(Policy):
            name = "never-train-test"

            def decide_loop(self, sim, t, waiting, state):
                return 0, 0.0

        try:
            assert "never-train-test" in registered_policies()
            r = run_experiment(Scenario(policy="never-train-test",
                                        n_users=4, horizon_s=100))
            assert r.updates == 0
            # no vectorized hook -> auto resolves to the loop oracle
            sim = Scenario(policy="never-train-test", n_users=4,
                           horizon_s=100).build()
            assert sim.resolve_engine() == "loop"
            with pytest.raises(ValueError, match="vectorized"):
                FederatedSim(SimConfig(policy="never-train-test",
                                       engine="vectorized")).run()
        finally:
            from repro.core import policies as _p
            _p._REGISTRY.pop("never-train-test", None)
            _p._INSTANCES.pop("never-train-test", None)

    def test_simconfig_accepts_policy_object(self):
        cfg = SimConfig(policy=GreedyThresholdPolicy(theta=0.1))
        assert FederatedSim(cfg).policy.theta == 0.1

    def test_simconfig_rejects_unknown_string(self):
        with pytest.raises(ValueError, match="policy"):
            SimConfig(policy="bogus")


# ---------------------------------------------------------------------------
# New arrival processes: shapes, seeding, semantics
# ---------------------------------------------------------------------------
class TestArrivalProcesses:
    @pytest.mark.parametrize("proc", [
        BernoulliArrivals(0.01),
        DiurnalArrivals(p_mean=0.01, period_s=500.0),
        MarkovModulatedArrivals(),
    ])
    def test_shapes_and_dtypes(self, proc):
        rng = np.random.default_rng(0)
        sched, choice = proc.sample(rng, 300, 7, 8)
        assert sched.shape == (300, 7) and sched.dtype == bool
        assert choice.shape == (300, 7)
        assert choice.min() >= 0 and choice.max() < 8

    @pytest.mark.parametrize("name", ["bernoulli", "diurnal", "bursty"])
    def test_seeded_determinism(self, name):
        proc = resolve_arrival(name)
        a = proc.sample(np.random.default_rng(5), 200, 4, 8)
        b = proc.sample(np.random.default_rng(5), 200, 4, 8)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_bernoulli_matches_legacy_default(self):
        """The default arrival path consumes the rng stream exactly like
        the pre-registry simulator: shuffle, then mask, then choices."""
        cfg = SimConfig(policy="online", horizon_s=500, n_users=6, seed=3,
                        app_arrival_p=0.02)
        sim = FederatedSim(cfg)
        rng = np.random.default_rng(3)
        names = [["Nexus6", "Nexus6P", "Hikey970", "Pixel2"][i % 4]
                 for i in range(6)]
        rng.shuffle(names)
        sched = rng.random((500, 6)) < 0.02
        choice = rng.integers(0, 8, (500, 6))
        np.testing.assert_array_equal(sim.app_sched, sched)
        np.testing.assert_array_equal(sim.app_choice, choice)

    def test_diurnal_rate_profile(self):
        proc = DiurnalArrivals(p_mean=0.01, depth=1.0, period_s=100.0)
        rate = proc.rate(100)
        assert rate.min() >= 0.0 and rate.max() <= 0.02 + 1e-12
        assert rate[25] == pytest.approx(0.02)    # peak at quarter period
        # higher-rate slots produce more arrivals in aggregate
        rng = np.random.default_rng(1)
        sched, _ = proc.sample(rng, 10000, 50, 8)
        peak_half = sched[:5000].sum()
        trough_half = sched[5000:].sum()
        assert peak_half + trough_half > 0

    def test_bursty_clumps_arrivals(self):
        """Burst phases concentrate arrivals: the per-user variance of
        slot counts must exceed an i.i.d. Bernoulli of the same mean."""
        rng = np.random.default_rng(0)
        proc = MarkovModulatedArrivals(p_calm=1e-4, p_burst=0.2,
                                       burst_start=5e-3, burst_stop=5e-2)
        sched, _ = proc.sample(rng, 4000, 64, 8)
        # window counts (100-slot windows): bursty => overdispersed
        w = sched.reshape(40, 100, 64).sum(axis=1).astype(float)
        mean, var = w.mean(), w.var()
        assert var > 2.0 * mean        # Poisson/Bernoulli would have var~mean

    def test_trace_replay_and_wrap(self):
        base = np.zeros((50, 3), dtype=bool)
        base[7, 1] = base[20, 2] = True
        tr = TraceArrivals(base, np.full((50, 3), 2))
        rng = np.random.default_rng(0)
        sched, choice = tr.sample(rng, 120, 3, 8)
        assert sched.shape == (120, 3)
        assert sched[7, 1] and sched[57, 1] and sched[107, 1]   # wrapped
        assert (choice == 2).all()

    def test_trace_user_mismatch_raises(self):
        tr = TraceArrivals(np.zeros((10, 3), dtype=bool))
        with pytest.raises(ValueError, match="users"):
            tr.sample(np.random.default_rng(0), 10, 5, 8)

    def test_trace_from_sim_roundtrip(self):
        sc = Scenario(policy="immediate", n_users=5, horizon_s=400, seed=9,
                      app_arrival_p=0.05)
        sim = sc.build()
        # replay pins the arrival schedule even under a different seed
        # (the seed still drives the fleet shuffle, which is independent)
        replay_sim = Scenario(policy="immediate",
                              arrivals=TraceArrivals.from_sim(sim),
                              n_users=5, horizon_s=400, seed=123).build()
        np.testing.assert_array_equal(replay_sim.app_sched, sim.app_sched)
        np.testing.assert_array_equal(replay_sim.app_choice, sim.app_choice)

    def test_bernoulli_string_honors_configured_rate(self):
        """arrivals="bernoulli" must mean the same as the default — the
        paper process at cfg.app_arrival_p, not a hard-coded 0.001."""
        kw = dict(policy="immediate", app_arrival_p=0.05, n_users=10,
                  horizon_s=500, seed=0)
        a = run_experiment(Scenario(**kw))
        b = run_experiment(Scenario(arrivals="bernoulli", **kw))
        assert a.corun_fraction == b.corun_fraction
        assert a.energy_j == b.energy_j

    def test_sim_rejects_bad_shapes(self):
        class _Broken(BernoulliArrivals):
            def sample(self, rng, T, n_users, n_apps, t_d=1.0):
                return np.zeros((3, 2), bool), np.zeros((3, 2), np.int64)
        with pytest.raises(ValueError, match="shape"):
            FederatedSim(SimConfig(policy="online", horizon_s=100,
                                   n_users=4), arrivals=_Broken())


# ---------------------------------------------------------------------------
# New fleets
# ---------------------------------------------------------------------------
class TestFleets:
    def test_paper_fleet_matches_legacy_assignment(self):
        spec = PaperFleet().build(np.random.default_rng(2), 12)
        rng = np.random.default_rng(2)
        names = [["Nexus6", "Nexus6P", "Hikey970", "Pixel2"][i % 4]
                 for i in range(12)]
        rng.shuffle(names)
        assert [d.name for d in spec.devices] == names

    def test_synthetic_fleet_builds_and_is_seeded(self):
        fl = SyntheticFleet(n_types=10, spread=0.4)
        a = fl.build(np.random.default_rng(4), 30)
        b = fl.build(np.random.default_rng(4), 30)
        assert a.tables.p_train.shape == (10,)
        assert a.tables.p_corun.shape == (10, 8)
        np.testing.assert_array_equal(a.device_ids, b.device_ids)
        np.testing.assert_array_equal(a.tables.p_train, b.tables.p_train)
        # power ordering preserved per device: P^{a'} > P^a, savings > 0
        assert (a.tables.p_corun > a.tables.p_app).all()
        assert (a.tables.saving_rate > 0).all()

    def test_custom_fleet_round_robin(self):
        fl = CustomCatalogFleet([TESTBED["Pixel2"], TESTBED["Nexus6"]])
        spec = fl.build(np.random.default_rng(0), 5)
        assert [d.name for d in spec.devices] == \
            ["Pixel2", "Nexus6", "Pixel2", "Nexus6", "Pixel2"]
        np.testing.assert_array_equal(spec.device_ids, [0, 1, 0, 1, 0])

    def test_custom_fleet_validates_app_coverage(self):
        import dataclasses as dc
        bad = dc.replace(TESTBED["Pixel2"], apps={})
        with pytest.raises(ValueError, match="apps"):
            CustomCatalogFleet([bad])

    def test_empty_catalog_rejected(self):
        with pytest.raises(ValueError, match="empty"):
            CustomCatalogFleet([])

    @pytest.mark.parametrize("fleet", [
        SyntheticFleet(n_types=6, spread=0.2),
        CustomCatalogFleet([TESTBED["Pixel2"], TESTBED["Nexus6P"]],
                           assignment="random"),
    ])
    def test_engine_parity_on_non_paper_fleet(self, fleet):
        # tight L_b builds staleness pressure fast enough for the online
        # policy to schedule inside the short horizon (the paper's
        # L_b=1000 is calibrated for 25 users x 3 h) — and exercises
        # decide_batch's sequential in-slot coupling path on both engines
        kw = dict(n_users=14, horizon_s=1200, seed=6, app_arrival_p=0.01,
                  V=2000.0, L_b=2.0)
        a = Scenario(policy="online", fleet=fleet, engine="loop", **kw).run()
        b = Scenario(policy="online", fleet=fleet, engine="vectorized",
                     **kw).run()
        assert a.updates > 0
        assert_equivalent(a, b)


# ---------------------------------------------------------------------------
# The new greedy policy: end-to-end + engine parity
# ---------------------------------------------------------------------------
class TestGreedyPolicy:
    @pytest.mark.parametrize("kw", [
        dict(horizon_s=2000, n_users=12, seed=2),
        dict(horizon_s=1500, n_users=16, seed=7, app_arrival_p=0.01),
    ])
    def test_loop_vs_vectorized_parity(self, kw):
        a = run_experiment(Scenario(policy="greedy", engine="loop", **kw))
        b = run_experiment(Scenario(policy="greedy", engine="vectorized",
                                    **kw))
        assert a.updates > 0
        assert_equivalent(a, b)

    def test_parity_with_custom_params(self):
        pol = GreedyThresholdPolicy(theta=0.5, patience=60)
        kw = dict(n_users=10, horizon_s=1500, seed=4, app_arrival_p=0.02)
        a = Scenario(policy=pol, engine="loop", **kw).run()
        b = Scenario(policy=pol, engine="vectorized", **kw).run()
        assert_equivalent(a, b)

    def test_zero_patience_degenerates_to_immediate(self):
        kw = dict(n_users=12, horizon_s=1500, seed=2)
        g = run_experiment(Scenario(
            policy=GreedyThresholdPolicy(theta=-1.0, patience=0), **kw))
        i = run_experiment(Scenario(policy="immediate", **kw))
        assert g.updates == i.updates
        assert g.energy_j == pytest.approx(i.energy_j, rel=1e-12)

    def test_greedy_runs_on_jax(self):
        """The carry protocol carries greedy's wait counters through the
        scan: engine='jax' resolves to the jax engine (it used to degrade
        to vectorized) and reproduces the loop schedule."""
        import jax
        sim = Scenario(policy="greedy", engine="jax", n_users=8,
                       horizon_s=300).build()
        assert sim.resolve_engine() == "jax"
        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        try:
            kw = dict(n_users=10, horizon_s=1200, seed=4, app_arrival_p=0.02)
            a = Scenario(policy="greedy", engine="loop", **kw).run()
            b = Scenario(policy="greedy", engine="jax", **kw).run()
        finally:
            jax.config.update("jax_enable_x64", prev)
        assert a.updates > 0
        assert_equivalent(a, b)

    def test_jax_request_degrades_to_loop_for_loop_only_policy(self):
        class _LoopOnly(Policy):
            name = "loop-only-test"

            def decide_loop(self, sim, t, waiting, state):
                return 0, 0.0

        sim = FederatedSim(SimConfig(policy=_LoopOnly(), engine="jax",
                                     n_users=4, horizon_s=100))
        assert sim.resolve_engine() == "loop"

    def test_fresh_policy_instances_share_jax_jit_cache(self):
        """Object-passing style (a new OnlinePolicy() per run) must not
        recompile the scan: policies key the cache by class, with
        instance knobs delivered through scan_operands."""
        from repro.core import GreedyThresholdPolicy, OnlinePolicy
        from repro.core.vector_engine import _jax_chunk_fn
        a = _jax_chunk_fn(8, 100, 100, OnlinePolicy(), False, False, 0)
        b = _jax_chunk_fn(8, 100, 100, OnlinePolicy(), False, False, 0)
        assert a is b
        # knob-carrying policies share too: theta/patience are traced
        g1 = _jax_chunk_fn(8, 100, 100, GreedyThresholdPolicy(0.1, 10),
                           False, False, 0)
        g2 = _jax_chunk_fn(8, 100, 100, GreedyThresholdPolicy(0.9, 999),
                           False, False, 0)
        assert g1 is g2

    def test_waits_for_cheap_slots(self):
        """With a tight threshold and long patience the greedy policy
        schedules later (fewer updates) than immediate but cheaper
        per-update energy on co-run-friendly devices."""
        kw = dict(n_users=16, horizon_s=3000, seed=1, app_arrival_p=0.02)
        g = run_experiment(Scenario(
            policy=GreedyThresholdPolicy(theta=0.3, patience=600), **kw))
        i = run_experiment(Scenario(policy="immediate", **kw))
        assert 0 < g.updates < i.updates
        assert g.energy_j < i.energy_j


# ---------------------------------------------------------------------------
# New arrivals end-to-end through run_experiment, loop vs vectorized
# ---------------------------------------------------------------------------
class TestArrivalEngineParity:
    @pytest.mark.parametrize("arrivals", [
        DiurnalArrivals(p_mean=0.02, period_s=400.0),
        MarkovModulatedArrivals(p_calm=1e-3, p_burst=0.1,
                                burst_start=5e-3, burst_stop=2e-2),
    ])
    @pytest.mark.parametrize("policy", ["online", "greedy", "offline"])
    def test_loop_vs_vectorized(self, arrivals, policy):
        # see test_engine_parity_on_non_paper_fleet for the L_b choice
        kw = dict(n_users=12, horizon_s=1500, seed=8, V=2000.0, L_b=2.0)
        a = Scenario(policy=policy, arrivals=arrivals, engine="loop",
                     **kw).run()
        b = Scenario(policy=policy, arrivals=arrivals, engine="vectorized",
                     **kw).run()
        assert a.updates > 0
        assert_equivalent(a, b)


# ---------------------------------------------------------------------------
# Scenario / run_experiment surface
# ---------------------------------------------------------------------------
class TestScenarioSurface:
    def test_kwargs_build(self):
        sc = Scenario(policy="online", n_users=7, horizon_s=300)
        assert sc.config.n_users == 7 and sc.policy.name == "online"

    def test_prebuilt_config(self):
        cfg = SimConfig(policy="sync", n_users=5, horizon_s=200)
        sc = Scenario(config=cfg)
        assert sc.policy.name == "sync"
        sc2 = Scenario(policy="immediate", config=cfg)
        assert sc2.policy.name == "immediate"      # explicit override wins
        assert cfg.policy == "sync"                # original untouched

    def test_config_and_kwargs_conflict(self):
        with pytest.raises(ValueError, match="config"):
            Scenario(config=SimConfig(), n_users=4)

    def test_run_experiment_kwargs_or_scenario(self):
        r = run_experiment(policy="immediate", n_users=4, horizon_s=300,
                           seed=0)
        assert r.updates > 0
        with pytest.raises(TypeError, match="Scenario"):
            run_experiment(Scenario(policy="immediate"), n_users=4)

    def test_repr_mentions_composition(self):
        sc = Scenario(policy="greedy", arrivals="bursty", fleet="synthetic",
                      n_users=3, horizon_s=100)
        s = repr(sc)
        assert "greedy" in s and "bursty" in s and "synthetic" in s
