"""Serving tier: sharded async parameter server + push ingestion.

Pins the subsystem's consistency contract:

- the sharded server is a semantic twin of ``core/server.
  AsyncParameterServer`` (same lags, weights, params, gap bookkeeping);
- a push commits atomically — no reader ever observes a partially
  applied push, single-threaded or under a concurrent reader;
- island death mid-push loses nothing: the in-flight shards are parked
  at eviction, re-queued at re-registration, and the push is applied
  exactly once;
- compressed pushes round-trip within codec tolerance, and the top-k
  delta stream converges to the uncompressed fixed point.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.server import AsyncParameterServer
from repro.fault.monitor import FleetMonitor
from repro.serve import (IngestPipeline, PushQueue, ServeClient, ShardPacket,
                         ShardSpec, ShardedAsyncParameterServer,
                         resolve_codec)


def tiny_params(n=13, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(0, 1, (2, 5)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(0, 1, n - 10).astype(np.float32))}


def flat_of(server):
    shards, version = server.snapshot_flat()
    return np.asarray(server.spec.join(shards)), version


# ---------------------------------------------------------------------------
# ShardSpec
# ---------------------------------------------------------------------------
class TestShardSpec:
    def test_flatten_unflatten_roundtrip_mixed_dtypes(self):
        params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                  "b": jnp.asarray([1, 2, 3], jnp.int32),
                  "c": jnp.float32(7.0)}
        spec = ShardSpec(params, 3)
        out = spec.unflatten(spec.flatten(params))
        for k in params:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(params[k]))
            assert out[k].dtype == params[k].dtype

    def test_boundaries_cover_total_near_equal(self):
        spec = ShardSpec({"w": jnp.zeros(10)}, 3)
        assert spec.boundaries == (0, 4, 7, 10)
        assert sum(spec.shard_size(i) for i in range(3)) == spec.total

    def test_split_join_roundtrip(self):
        spec = ShardSpec({"w": jnp.zeros(11)}, 4)
        flat = jnp.arange(11, dtype=jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(spec.join(spec.split(flat))), np.asarray(flat))

    def test_more_shards_than_params_gives_empty_shards(self):
        spec = ShardSpec({"w": jnp.zeros(2)}, 5)
        sizes = [spec.shard_size(i) for i in range(5)]
        assert sum(sizes) == 2 and 0 in sizes
        flat = jnp.asarray([3.0, 4.0])
        np.testing.assert_array_equal(
            np.asarray(spec.join(spec.split(flat))), [3.0, 4.0])

    def test_rejects_bad_inputs(self):
        with pytest.raises(ValueError, match="n_shards"):
            ShardSpec({"w": jnp.zeros(4)}, 0)
        spec = ShardSpec({"w": jnp.zeros(4)}, 2)
        with pytest.raises(ValueError, match="shape"):
            spec.unflatten(jnp.zeros(3))
        with pytest.raises(ValueError, match="slices"):
            spec.join([jnp.zeros(4)])


# ---------------------------------------------------------------------------
# ShardedAsyncParameterServer vs the core server
# ---------------------------------------------------------------------------
class TestShardedServerParity:
    @pytest.mark.parametrize("aggregation",
                             ["replace", "fedasync_poly", "gap_aware"])
    @pytest.mark.parametrize("n_shards", [1, 4])
    def test_matches_core_server_stream(self, aggregation, n_shards):
        """Same interleaved pull/push stream -> same lags, same weights,
        same params, same momentum-norm bookkeeping (up to the float
        reduction-order difference of the sharded norm)."""
        params = tiny_params()
        core = AsyncParameterServer(params, eta=0.05, beta=0.9,
                                    aggregation=aggregation)
        shd = ShardedAsyncParameterServer(params, eta=0.05, beta=0.9,
                                          aggregation=aggregation,
                                          n_shards=n_shards)
        rng = np.random.default_rng(1)
        pulled = {}
        for step in range(12):
            cid = step % 3
            if cid not in pulled:
                p_c, vc = core.pull(cid)
                p_s, vs = shd.pull(cid)
                assert vc == vs
                pulled[cid] = jax.tree.map(
                    lambda x: x + jnp.asarray(
                        rng.normal(0, 0.1, x.shape).astype(np.float32)),
                    p_c)
            if step % 2 == 1:       # stale pushes: half the pulls linger
                new = pulled.pop(cid)
                rc = core.push(cid, new)
                rs = shd.push(cid, new)
                assert rc.lag == rs.lag
                assert rc.version == rs.version
                assert rc.applied_weight == pytest.approx(
                    rs.applied_weight, rel=1e-5, abs=1e-7)
        shd.assert_consistent()
        for a, b in zip(jax.tree.leaves(core.params),
                        jax.tree.leaves(shd.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        assert shd.v_norm == pytest.approx(core.v_norm, rel=1e-4, abs=1e-7)

    def test_lag_estimate_counts_concurrent_tasks(self):
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=2)
        shd.pull(0)
        shd.pull(1)
        assert shd.lag_estimate(0) == 1      # the other in-flight task
        assert shd.lag_estimate(9) == 2

    def test_params_setter_resplits_and_republishes(self):
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=3)
        new = jax.tree.map(lambda x: x * 0 + 5.0, shd.params)
        shd.params = new
        flat, version = flat_of(shd)
        assert version == 0                  # restore does not bump
        np.testing.assert_array_equal(flat, 5.0)
        shd.assert_consistent()

    def test_history_ring_serves_old_bases_then_ages_out(self):
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=2, history_depth=3)
        snaps = {0: flat_of(shd)[0]}
        for k in range(5):
            p, _ = shd.pull(0)
            shd.push(0, jax.tree.map(lambda x: x + 1.0, p))
            snaps[k + 1] = flat_of(shd)[0]
        # ring keeps the last 3 versions
        for v in (3, 4, 5):
            got = np.concatenate([
                np.asarray(shd.base_shard(v, i)) for i in range(2)])
            np.testing.assert_array_equal(got, snaps[v])
        assert shd.base_shard(0, 0) is None
        assert shd.ring_misses == 1

    def test_rejects_wrong_slice_count_and_bad_history_depth(self):
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=2)
        with pytest.raises(ValueError, match="slices"):
            shd.push_flat(0, [jnp.zeros(13)])
        with pytest.raises(ValueError, match="history_depth"):
            ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                        history_depth=0)


# ---------------------------------------------------------------------------
# Atomic publish: partial application is never observable
# ---------------------------------------------------------------------------
class TestAtomicPublish:
    def test_reader_never_sees_partial_push_concurrently(self):
        """A reader thread hammering snapshots while uniform-constant
        pushes stream in must only ever see uniform vectors whose value
        equals the paired version — a torn (partially applied) push
        would surface as a mixed vector or a version/value mismatch."""
        params = {"w": jnp.zeros(64, jnp.float32)}
        shd = ShardedAsyncParameterServer(params, eta=0.05, beta=0.9,
                                          n_shards=4)
        errors = []
        stop = threading.Event()

        def reader():
            while not stop.is_set():
                flat, version = flat_of(shd)
                if not np.all(flat == flat[0]):
                    errors.append(("torn", flat.copy(), version))
                    return
                if flat[0] != float(version):
                    errors.append(("mismatch", float(flat[0]), version))
                    return

        t = threading.Thread(target=reader)
        t.start()
        try:
            for k in range(50):
                shd.pull(0)
                shd.push(0, {"w": jnp.full(64, float(k + 1), jnp.float32)})
        finally:
            stop.set()
            t.join()
        assert errors == []
        shd.assert_consistent()

    def test_staged_partial_push_is_invisible(self):
        """Single-threaded twin: with only 2 of 3 shard packets staged,
        readers still see the pre-push snapshot and version."""
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=3)
        pipe = IngestPipeline(shd)
        client = ServeClient(0, pipe)
        before, v0 = flat_of(shd)
        client.pull()
        client.push(jnp.asarray(before) + 1.0, slot=0, shards=[0, 1])
        pipe.drain()
        assert pipe.pending_pushes == 1
        after, v1 = flat_of(shd)
        assert v1 == v0
        np.testing.assert_array_equal(after, before)


# ---------------------------------------------------------------------------
# Ingestion pipeline
# ---------------------------------------------------------------------------
class TestIngestPipeline:
    def test_happy_path_commits_and_records_latency(self):
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=3)
        pipe = IngestPipeline(shd)
        clients = [ServeClient(i, pipe) for i in range(4)]
        for t in range(3):
            for c in clients:
                base, _ = c.pull()
                c.push(base + 0.5, slot=t)
            pipe.drain()
        assert pipe.stats.applied == 12
        assert shd.version == 12
        assert len(pipe.latencies) == 12
        assert all(l >= 0 for l in pipe.latencies)
        shd.assert_consistent()

    def test_backpressure_rejects_when_full(self):
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=4)
        pipe = IngestPipeline(shd, capacity=6)     # room for 1.5 pushes
        c0, c1 = ServeClient(0, pipe), ServeClient(1, pipe)
        base0, _ = c0.pull()
        base1, _ = c1.pull()
        _, acc0 = c0.push(base0 + 1, slot=0)
        _, acc1 = c1.push(base1 + 1, slot=0)
        assert acc0 == 4 and acc1 == 2             # queue filled mid-push
        assert pipe.stats.rejected == 2
        pipe.drain()
        assert pipe.stats.applied == 1             # only the complete push
        assert pipe.pending_pushes == 1            # partial stays staged
        # retry of the rejected shards completes the second push
        c1.resume_push(0, base1 + 1, slot=1)
        pipe.drain()
        assert pipe.stats.applied == 2
        assert pipe.pending_pushes == 0

    def test_rejects_bad_capacity(self):
        with pytest.raises(ValueError, match="capacity"):
            PushQueue(0)

    def test_unknown_codec_rejected(self):
        with pytest.raises(ValueError, match="codec"):
            resolve_codec("gzip")

    def test_int8_push_roundtrip_fidelity(self):
        """int8 wire quantization: per-shard error bounded by half the
        shard's quantization scale."""
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=3)
        pipe = IngestPipeline(shd, codec="int8")
        c = ServeClient(0, pipe)
        base, _ = c.pull()
        target = np.asarray(base) + np.linspace(-2, 2, 13, dtype=np.float32)
        c.push(jnp.asarray(target), slot=0)
        pipe.drain()
        got, version = flat_of(shd)
        assert version == 1
        for i in range(3):
            sl = shd.spec.shard_slice(i)
            scale = max(np.abs(target[sl]).max() / 127.0, 1e-12)
            assert np.abs(got[sl] - target[sl]).max() <= scale * 0.5 + 1e-6

    def test_topk_delta_stream_converges_to_uncompressed_fixed_point(self):
        """The acceptance property at the pipeline level: a contraction
        push stream through the top-k delta codec lands on the same
        fixed point as the uncompressed stream."""
        params = {"w": jnp.zeros(48, jnp.float32)}
        target = jnp.asarray(np.random.default_rng(3).normal(0, 1, 48)
                             .astype(np.float32))

        def run(codec, steps=300):
            shd = ShardedAsyncParameterServer(params, eta=0.05, beta=0.9,
                                              n_shards=4)
            pipe = IngestPipeline(shd, codec=codec)
            c = ServeClient(0, pipe)
            for t in range(steps):
                base, _ = c.pull()
                c.push(base + 0.05 * (target - base), slot=t)
                pipe.drain()
            return flat_of(shd)[0]

        ref = run(None)
        np.testing.assert_allclose(ref, np.asarray(target), atol=1e-3)
        compressed = run(resolve_codec("topk"))
        np.testing.assert_allclose(compressed, np.asarray(target), atol=1e-2)

    def test_topk_ring_miss_falls_back_and_counts(self):
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=2, history_depth=1)
        pipe = IngestPipeline(shd, codec="topk")
        stale, fresh = ServeClient(0, pipe), ServeClient(1, pipe)
        stale.pull()                     # base = version 0
        for t in range(3):               # ring depth 1: version 0 ages out
            base, _ = fresh.pull()
            fresh.push(base + 0.1, slot=t)
            pipe.drain()
        stale.push(jnp.asarray(flat_of(shd)[0]) + 0.1, slot=3)
        pipe.drain()
        assert pipe.stats.ring_misses == 2      # one per shard packet
        assert pipe.stats.applied == 4


class TestIslandDeathMidPush:
    def make(self, timeout=3, n_shards=3):
        shd = ShardedAsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                          n_shards=n_shards)
        pipe = IngestPipeline(shd, monitor=FleetMonitor(timeout_slots=timeout))
        return shd, pipe

    def test_push_survives_death_applied_exactly_once(self):
        """The acceptance scenario: island dies after 2 of 3 shards,
        gets evicted, recovers, re-sends the missing shard — the push
        commits exactly once and the final params are exact."""
        shd, pipe = self.make()
        c = ServeClient(7, pipe)
        base, _ = c.pull()
        target = jnp.asarray(base) + 1.0
        pid, _ = c.push(target, slot=0, shards=[0, 1])     # dies here
        pipe.drain()
        dead = pipe.sweep(10)
        assert dead == {7}
        assert pipe.stats.evicted == 1
        assert pipe.parked_clients == {7}
        assert 7 not in pipe.monitor.active
        before, v = flat_of(shd)
        assert v == 0                                       # nothing applied
        c.resume_push(pid, target, slot=11)                 # recovery
        pipe.drain()
        assert pipe.stats.reregistered == 1
        assert 7 in pipe.monitor.active                     # re-registered
        got, v = flat_of(shd)
        assert v == 1                                       # exactly once
        assert pipe.stats.applied == 1
        np.testing.assert_allclose(got, np.asarray(target), rtol=1e-6)
        assert pipe.parked_clients == set()
        shd.assert_consistent()

    def test_queued_inflight_shards_are_requeued_not_lost(self):
        """Death with packets still IN THE QUEUE: eviction parks them,
        re-registration re-queues them, and they count toward the same
        single apply."""
        shd, pipe = self.make()
        c = ServeClient(3, pipe)
        base, _ = c.pull()
        target = jnp.asarray(base) + 2.0
        pid, acc = c.push(target, slot=0)       # all 3 packets queued
        assert acc == 3
        pipe.step(1)                            # only shard 0 processed
        dead = pipe.sweep(8)                    # dies with 2 queued
        assert dead == {3}
        assert pipe.stats.parked_packets == 2
        assert len(pipe.queue) == 0
        assert flat_of(shd)[1] == 0
        # recovery: one fresh heartbeat packet re-queues the parked ones
        c.resume_push(pid, target, slot=9)      # nothing missing -> no-op
        assert pipe.parked_clients == {3}       # still parked (no packet)
        base2, _ = c.pull()
        pid2, _ = c.push(jnp.asarray(target) + 1.0, slot=9)
        pipe.drain()
        assert pipe.stats.requeued_packets == 2
        assert pipe.stats.applied == 2          # both pushes landed
        assert flat_of(shd)[1] == 2
        shd.assert_consistent()

    def test_full_resend_after_commit_is_deduped(self):
        """A client that re-sends a whole already-committed push (it
        never saw the ack) is dropped as duplicates — applied once."""
        shd, pipe = self.make()
        c = ServeClient(5, pipe)
        base, _ = c.pull()
        target = jnp.asarray(base) + 1.0
        pid, _ = c.push(target, slot=0)
        pipe.drain()
        assert pipe.stats.applied == 1
        # paranoid client re-sends the same push_id wholesale
        c._sent[pid].clear()
        c.resume_push(pid, target, slot=1)
        pipe.drain()
        assert pipe.stats.applied == 1
        assert pipe.stats.duplicates == 3       # one per shard packet
        assert flat_of(shd)[1] == 1

    def test_monitor_cadence_counts_pushes_not_packets(self):
        """Shard packets are liveness-only beats; only committed pushes
        feed the straggler EWMA — a 4-shard push is ONE cadence sample."""
        shd, pipe = self.make(n_shards=3)
        c = ServeClient(1, pipe)
        for t in range(3):
            base, _ = c.pull()
            c.push(jnp.asarray(base) + 0.1, slot=t)
            pipe.drain()
        assert pipe.monitor.straggler.workers[1].updates == 3


# ---------------------------------------------------------------------------
# launch/train.py integration: the island driver on the sharded store
# ---------------------------------------------------------------------------
class TestTrainDriverSharded:
    def test_island_driver_runs_on_sharded_server(self):
        from repro.configs import get_smoke_config
        from repro.launch.train import IslandConfig, run

        icfg = IslandConfig(n_islands=2, slots=100, local_steps=1, batch=4,
                            seq=32, eval_every=100, app_arrival_p=0.05,
                            n_shards=2, seed=5)
        out = run(get_smoke_config("qwen3-0.6b"), icfg, log=lambda *a: None)
        assert np.isfinite(out["final_loss"])
        assert out["updates"] >= 0
