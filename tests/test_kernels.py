"""Pallas kernels vs their pure-jnp oracles (interpret=True on CPU),
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.fused_update import (fused_apply_flat,
                                        fused_apply_flat_ref,
                                        fused_update_flat,
                                        fused_update_flat_ref,
                                        fused_weighted_apply_pallas,
                                        clamp_block_rows, kernel_interpret,
                                        resolve_kernel_mode)
from repro.kernels.fused_update.kernel import LANES
from repro.kernels.fused_update.ops import (DEFAULT_BLOCK_ROWS,
                                            MIN_BLOCK_ROWS,
                                            fused_momentum_gap_update_pallas)
from repro.kernels.ssd_scan import ssd_chunked_pallas, ssd_chunked_ref
from repro.models.ssm import ssd_chunked
from repro.optim.gap import fused_momentum_gap_update, fused_weighted_apply


class TestFusedUpdate:
    @pytest.mark.parametrize("n", [1, 100, 4096, 128 * 128 + 17, 777_777])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_ref(self, n, dtype):
        k = jax.random.PRNGKey(n)
        t, v, g = (jax.random.normal(kk, (n,), dtype)
                   for kk in jax.random.split(k, 3))
        a = fused_update_flat(t, v, g, 0.01, 0.9, block_rows=128,
                              interpret=True)
        b = fused_update_flat_ref(t, v, g, 0.01, 0.9)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=3e-5, atol=3e-5)

    @pytest.mark.parametrize("eta,beta", [(0.1, 0.0), (0.01, 0.9),
                                          (1e-3, 0.99)])
    def test_hyperparam_sweep(self, eta, beta):
        k = jax.random.PRNGKey(0)
        t, v, g = (jax.random.normal(kk, (5000,))
                   for kk in jax.random.split(k, 3))
        a = fused_update_flat(t, v, g, eta, beta, block_rows=128,
                              interpret=True)
        b = fused_update_flat_ref(t, v, g, eta, beta)
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                                   rtol=3e-5, atol=3e-5)

    def test_pytree_wrapper_matches_xla_fused(self):
        """kernels.fused_update.ops == optim.gap.fused_momentum_gap_update
        (the paper's Eq. 1 + Eq. 4 in one pass)."""
        k = jax.random.PRNGKey(1)
        ks = jax.random.split(k, 6)
        params = {"a": jax.random.normal(ks[0], (33, 7)),
                  "b": {"c": jax.random.normal(ks[1], (129,))}}
        v = {"a": jax.random.normal(ks[2], (33, 7)),
             "b": {"c": jax.random.normal(ks[3], (129,))}}
        g = {"a": jax.random.normal(ks[4], (33, 7)),
             "b": {"c": jax.random.normal(ks[5], (129,))}}
        p1, v1, gap1 = fused_momentum_gap_update(params, v, g, eta=0.05,
                                                 beta=0.9,
                                                 lag=jnp.int32(3))
        p2, v2, gap2 = fused_momentum_gap_update_pallas(
            params, v, g, eta=0.05, beta=0.9, lag=3, block_rows=128,
            interpret=True)
        for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=3e-5, atol=3e-5)
        assert float(gap1) == pytest.approx(float(gap2), rel=1e-4)


class TestFusedApply:
    """The server-push apply kernel (mix + momentum + sq-norm) vs its
    pure-jnp oracle."""

    @pytest.mark.parametrize("n", [1, 100, 4096, 128 * 128 + 17, 777_777])
    def test_matches_ref(self, n):
        k = jax.random.PRNGKey(n)
        cur, v, new = (jax.random.normal(kk, (n,))
                       for kk in jax.random.split(k, 3))
        a = fused_apply_flat(cur, v, new, 0.6, 1.0 / 0.01, 0.9,
                             block_rows=128, interpret=True)
        b = fused_apply_flat_ref(cur, v, new, 0.6, 1.0 / 0.01, 0.9)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=3e-5, atol=3e-5)

    @pytest.mark.parametrize("w,eta,beta", [
        (1.0, 0.1, 0.0),     # replace degenerates to w=1
        (0.6, 0.01, 0.9),
        (0.05, 1e-3, 0.99),
        (0.0, 0.05, 0.5),    # fully-stale push: model unchanged
    ])
    def test_knob_sweep(self, w, eta, beta):
        k = jax.random.PRNGKey(7)
        cur, v, new = (jax.random.normal(kk, (5000,))
                       for kk in jax.random.split(k, 3))
        a = fused_apply_flat(cur, v, new, w, 1.0 / eta, beta,
                             block_rows=128, interpret=True)
        b = fused_apply_flat_ref(cur, v, new, w, 1.0 / eta, beta)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=3e-5, atol=3e-5)

    def test_pytree_wrapper_matches_xla_fused(self):
        """fused_weighted_apply_pallas == optim.gap.fused_weighted_apply
        (the server apply contract) at rtol 1e-6."""
        k = jax.random.PRNGKey(1)
        ks = jax.random.split(k, 6)
        shape = {"a": (33, 7), "b": {"c": (129,)}}
        mk = lambda kk: {"a": jax.random.normal(kk[0], (33, 7)),
                         "b": {"c": jax.random.normal(kk[1], (129,))}}
        params, v, new = (mk(ks[2 * i:2 * i + 2]) for i in range(3))
        p1, v1, n1 = fused_weighted_apply(params, v, new, w=0.4, eta=0.05,
                                          beta=0.9)
        p2, v2, n2 = fused_weighted_apply_pallas(params, v, new, w=0.4,
                                                 eta=0.05, beta=0.9,
                                                 block_rows=128,
                                                 interpret=True)
        for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-7)
        for x, y in zip(jax.tree.leaves(v1), jax.tree.leaves(v2)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)
        assert float(n1) == pytest.approx(float(n2), rel=1e-5)

    def test_padding_contributes_nothing(self):
        """A size straddling a block boundary by one element: the padded
        lanes must add 0 to the norm (mixed/v' padding stays zero)."""
        n = 128 * 128 + 1
        k = jax.random.PRNGKey(n)
        cur, v, new = (jax.random.normal(kk, (n,))
                       for kk in jax.random.split(k, 3))
        _, _, sq = fused_apply_flat(cur, v, new, 0.3, 10.0, 0.9,
                                    block_rows=128, interpret=True)
        _, _, sq_ref = fused_apply_flat_ref(cur, v, new, 0.3, 10.0, 0.9)
        assert float(sq) == pytest.approx(float(sq_ref), rel=1e-5)


class TestBlockRowsClamp:
    """Satellite: block_rows auto-clamp for tiny params + empty guard
    (mirrors the topk k-clamp fix)."""

    def test_tiny_payload_shrinks_block(self):
        # a few hundred params should not pad to a 512 KiB block
        assert clamp_block_rows(300) == MIN_BLOCK_ROWS
        assert clamp_block_rows(LANES * MIN_BLOCK_ROWS) == MIN_BLOCK_ROWS

    def test_large_payload_keeps_requested_block(self):
        n = DEFAULT_BLOCK_ROWS * LANES * 4
        assert clamp_block_rows(n) == DEFAULT_BLOCK_ROWS

    def test_clamp_is_power_of_two_and_bounded(self):
        for n in (1, 7, 129, 1000, 10_000, 65_536, 10 ** 6):
            br = clamp_block_rows(n)
            assert MIN_BLOCK_ROWS <= br <= DEFAULT_BLOCK_ROWS
            assert br & (br - 1) == 0
            # pad waste bounded by one block
            rows = -(-n // LANES)
            padded_rows = -(-rows // br) * br
            assert padded_rows - rows < br or rows < MIN_BLOCK_ROWS

    def test_tiny_update_matches_ref(self):
        """The clamped path produces correct results for sub-block sizes."""
        for n in (1, 5, 129, 1025):
            k = jax.random.PRNGKey(n)
            t, v, g = (jax.random.normal(kk, (n,))
                       for kk in jax.random.split(k, 3))
            a = fused_update_flat(t, v, g, 0.01, 0.9, interpret=True)
            b = fused_update_flat_ref(t, v, g, 0.01, 0.9)
            for x, y in zip(a, b):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                           rtol=3e-5, atol=3e-5)

    def test_empty_arrays_short_circuit(self):
        z = jnp.zeros((0,), jnp.float32)
        t, v, sq = fused_update_flat(z, z, z, 0.01, 0.9, interpret=True)
        assert t.shape == (0,) and v.shape == (0,) and float(sq) == 0.0
        m, v2, sq2 = fused_apply_flat(z, z, z, 0.5, 10.0, 0.9,
                                      interpret=True)
        assert m.shape == (0,) and v2.shape == (0,) and float(sq2) == 0.0

    def test_mode_dispatch(self):
        assert resolve_kernel_mode("pallas") == "pallas"
        assert resolve_kernel_mode("reference") == "reference"
        auto = resolve_kernel_mode("auto")
        on_tpu = jax.default_backend() == "tpu"
        assert auto == ("pallas" if on_tpu else "reference")
        assert kernel_interpret() == (not on_tpu)
        with pytest.raises(ValueError, match="unknown kernel mode"):
            resolve_kernel_mode("bogus")


class TestFusedKernelProperties:
    """Hypothesis parity suite: both kernels (interpret mode) vs the
    optim/gap oracles over shapes x padding remainders x (eta, beta)."""

    @settings(max_examples=25, deadline=None,
              suppress_health_check=HealthCheck.all())
    @given(rows=st.integers(1, 40), rem=st.integers(0, LANES - 1),
           eta=st.floats(1e-4, 0.5), beta=st.floats(0.0, 0.99),
           seed=st.integers(0, 2 ** 16))
    def test_update_parity(self, rows, rem, eta, beta, seed):
        n = (rows - 1) * LANES + rem + 1   # spans rows, any lane remainder
        k = jax.random.PRNGKey(seed)
        t, v, g = (jax.random.normal(kk, (n,))
                   for kk in jax.random.split(k, 3))
        t2, v2, sq = fused_update_flat(t, v, g, eta, beta, interpret=True)
        tr, vr, sqr = fused_update_flat_ref(t, v, g, eta, beta)
        np.testing.assert_allclose(np.asarray(t2), np.asarray(tr),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(v2), np.asarray(vr),
                                   rtol=1e-6, atol=1e-6)
        assert float(sq) == pytest.approx(float(sqr), rel=1e-5, abs=1e-10)

    @settings(max_examples=25, deadline=None,
              suppress_health_check=HealthCheck.all())
    @given(rows=st.integers(1, 40), rem=st.integers(0, LANES - 1),
           w=st.floats(0.0, 1.0), eta=st.floats(1e-4, 0.5),
           beta=st.floats(0.0, 0.99), seed=st.integers(0, 2 ** 16))
    def test_apply_parity(self, rows, rem, w, eta, beta, seed):
        n = (rows - 1) * LANES + rem + 1   # spans rows, any lane remainder
        k = jax.random.PRNGKey(seed)
        cur, v, new = (jax.random.normal(kk, (n,))
                       for kk in jax.random.split(k, 3))
        inv_eta = 1.0 / eta
        m2, v2, sq = fused_apply_flat(cur, v, new, w, inv_eta, beta,
                                      interpret=True)
        mr, vr, sqr = fused_apply_flat_ref(cur, v, new, w, inv_eta, beta)
        np.testing.assert_allclose(np.asarray(m2), np.asarray(mr),
                                   rtol=1e-6, atol=1e-6)
        # v' suffers catastrophic cancellation scaled by inv_eta: a few
        # ulps of the LARGEST intermediate, not of the (near-zero) result
        # — so the absolute floor tracks the array scale
        v_scale = float(np.max(np.abs(np.asarray(vr)))) + 1.0
        np.testing.assert_allclose(np.asarray(v2), np.asarray(vr),
                                   rtol=1e-6, atol=1e-6 * v_scale)
        assert float(sq) == pytest.approx(float(sqr), rel=1e-5, abs=1e-10)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,KV,S,d", [
        (1, 4, 4, 256, 64),      # MHA
        (2, 8, 2, 256, 128),     # GQA 4:1
        (1, 4, 2, 384, 64),      # non-pow2 blocks count
        (1, 2, 1, 512, 32),      # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, B, H, KV, S, d, dtype):
        k0 = jax.random.PRNGKey(B * H * S)
        ks = jax.random.split(k0, 3)
        q = jax.random.normal(ks[0], (B, H, S, d), dtype)
        k = jax.random.normal(ks[1], (B, KV, S, d), dtype)
        v = jax.random.normal(ks[2], (B, KV, S, d), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64))
        k = jax.random.normal(ks[1], (1, 2, 256, 64))
        v = jax.random.normal(ks[2], (1, 2, 256, 64))
        out = flash_attention(q, k, v, causal=False, block_q=128,
                              block_k=128, interpret=True)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_model_sdpa(self):
        """Kernel output == the model's XLA einsum attention (its oracle in
        the model stack)."""
        from repro.models.attention import _sdpa, causal_mask
        from repro.models.config import ModelConfig
        cfg = ModelConfig(name="t", family="dense", num_layers=1,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=64, head_dim=16)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        B, S = 2, 256
        q = jax.random.normal(ks[0], (B, S, 4, 16))
        k = jax.random.normal(ks[1], (B, S, 2, 16))
        v = jax.random.normal(ks[2], (B, S, 2, 16))
        ref = _sdpa(q, k, v, causal_mask(S, S), cfg)
        out = flash_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True,
                              block_q=128, block_k=128, interpret=True)
        np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                                   np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("B,S,nh,ph,s,chunk", [
        (2, 64, 4, 16, 16, 16),
        (1, 128, 2, 32, 64, 32),
        (2, 96, 3, 8, 24, 32),
        (1, 64, 8, 64, 128, 16),
    ])
    def test_matches_naive_recurrence(self, B, S, nh, ph, s, chunk):
        k0 = jax.random.PRNGKey(B + S + nh)
        ks = jax.random.split(k0, 5)
        X = jax.random.normal(ks[0], (B, S, nh, ph))
        dtv = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        A = -jnp.exp(0.3 * jax.random.normal(ks[2], (nh,)))
        Bh = 0.5 * jax.random.normal(ks[3], (B, S, nh, s))
        Ch = 0.5 * jax.random.normal(ks[4], (B, S, nh, s))
        yr, fr = ssd_chunked_ref(X, dtv, A, Bh, Ch)
        yp, fp = ssd_chunked_pallas(X, dtv, A, Bh, Ch, chunk, interpret=True)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(fp), np.asarray(fr),
                                   rtol=1e-4, atol=1e-4)

    def test_model_xla_path_matches_naive(self):
        """models.ssm.ssd_chunked (the XLA default) == naive recurrence."""
        ks = jax.random.split(jax.random.PRNGKey(9), 5)
        X = jax.random.normal(ks[0], (2, 64, 4, 16))
        dtv = jax.nn.softplus(jax.random.normal(ks[1], (2, 64, 4)))
        A = -jnp.exp(0.3 * jax.random.normal(ks[2], (4,)))
        Bh = 0.5 * jax.random.normal(ks[3], (2, 64, 4, 16))
        Ch = 0.5 * jax.random.normal(ks[4], (2, 64, 4, 16))
        yr, fr = ssd_chunked_ref(X, dtv, A, Bh, Ch)
        yx, fx = ssd_chunked(X, dtv, A, Bh, Ch, 16)
        np.testing.assert_allclose(np.asarray(yx), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)

    def test_init_state_continuation(self):
        """Splitting a sequence across two calls with state carry == one call
        (prefill-continuation correctness)."""
        ks = jax.random.split(jax.random.PRNGKey(4), 5)
        B, S, nh, ph, s, chunk = 1, 64, 2, 8, 16, 16
        X = jax.random.normal(ks[0], (B, S, nh, ph))
        dtv = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        A = -jnp.exp(0.3 * jax.random.normal(ks[2], (nh,)))
        Bh = 0.5 * jax.random.normal(ks[3], (B, S, nh, s))
        Ch = 0.5 * jax.random.normal(ks[4], (B, S, nh, s))
        y_all, f_all = ssd_chunked_pallas(X, dtv, A, Bh, Ch, chunk,
                                          interpret=True)
        h = S // 2
        y1, f1 = ssd_chunked_pallas(X[:, :h], dtv[:, :h], A, Bh[:, :h],
                                    Ch[:, :h], chunk, interpret=True)
        y2, f2 = ssd_chunked_pallas(X[:, h:], dtv[:, h:], A, Bh[:, h:],
                                    Ch[:, h:], chunk, init_state=f1,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, h:]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(f2), np.asarray(f_all),
                                   rtol=1e-4, atol=1e-4)
