"""Pallas kernels vs their pure-jnp oracles (interpret=True on CPU),
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.fused_update import (fused_update_flat,
                                        fused_update_flat_ref)
from repro.kernels.fused_update.ops import fused_momentum_gap_update_pallas
from repro.kernels.ssd_scan import ssd_chunked_pallas, ssd_chunked_ref
from repro.models.ssm import ssd_chunked
from repro.optim.gap import fused_momentum_gap_update


class TestFusedUpdate:
    @pytest.mark.parametrize("n", [1, 100, 4096, 128 * 128 + 17, 777_777])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_ref(self, n, dtype):
        k = jax.random.PRNGKey(n)
        t, v, g = (jax.random.normal(kk, (n,), dtype)
                   for kk in jax.random.split(k, 3))
        a = fused_update_flat(t, v, g, 0.01, 0.9, block_rows=128,
                              interpret=True)
        b = fused_update_flat_ref(t, v, g, 0.01, 0.9)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=3e-5, atol=3e-5)

    @pytest.mark.parametrize("eta,beta", [(0.1, 0.0), (0.01, 0.9),
                                          (1e-3, 0.99)])
    def test_hyperparam_sweep(self, eta, beta):
        k = jax.random.PRNGKey(0)
        t, v, g = (jax.random.normal(kk, (5000,))
                   for kk in jax.random.split(k, 3))
        a = fused_update_flat(t, v, g, eta, beta, block_rows=128,
                              interpret=True)
        b = fused_update_flat_ref(t, v, g, eta, beta)
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                                   rtol=3e-5, atol=3e-5)

    def test_pytree_wrapper_matches_xla_fused(self):
        """kernels.fused_update.ops == optim.gap.fused_momentum_gap_update
        (the paper's Eq. 1 + Eq. 4 in one pass)."""
        k = jax.random.PRNGKey(1)
        ks = jax.random.split(k, 6)
        params = {"a": jax.random.normal(ks[0], (33, 7)),
                  "b": {"c": jax.random.normal(ks[1], (129,))}}
        v = {"a": jax.random.normal(ks[2], (33, 7)),
             "b": {"c": jax.random.normal(ks[3], (129,))}}
        g = {"a": jax.random.normal(ks[4], (33, 7)),
             "b": {"c": jax.random.normal(ks[5], (129,))}}
        p1, v1, gap1 = fused_momentum_gap_update(params, v, g, eta=0.05,
                                                 beta=0.9,
                                                 lag=jnp.int32(3))
        p2, v2, gap2 = fused_momentum_gap_update_pallas(
            params, v, g, eta=0.05, beta=0.9, lag=3, block_rows=128,
            interpret=True)
        for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=3e-5, atol=3e-5)
        assert float(gap1) == pytest.approx(float(gap2), rel=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,KV,S,d", [
        (1, 4, 4, 256, 64),      # MHA
        (2, 8, 2, 256, 128),     # GQA 4:1
        (1, 4, 2, 384, 64),      # non-pow2 blocks count
        (1, 2, 1, 512, 32),      # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, B, H, KV, S, d, dtype):
        k0 = jax.random.PRNGKey(B * H * S)
        ks = jax.random.split(k0, 3)
        q = jax.random.normal(ks[0], (B, H, S, d), dtype)
        k = jax.random.normal(ks[1], (B, KV, S, d), dtype)
        v = jax.random.normal(ks[2], (B, KV, S, d), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64))
        k = jax.random.normal(ks[1], (1, 2, 256, 64))
        v = jax.random.normal(ks[2], (1, 2, 256, 64))
        out = flash_attention(q, k, v, causal=False, block_q=128,
                              block_k=128, interpret=True)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_model_sdpa(self):
        """Kernel output == the model's XLA einsum attention (its oracle in
        the model stack)."""
        from repro.models.attention import _sdpa, causal_mask
        from repro.models.config import ModelConfig
        cfg = ModelConfig(name="t", family="dense", num_layers=1,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=64, head_dim=16)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        B, S = 2, 256
        q = jax.random.normal(ks[0], (B, S, 4, 16))
        k = jax.random.normal(ks[1], (B, S, 2, 16))
        v = jax.random.normal(ks[2], (B, S, 2, 16))
        ref = _sdpa(q, k, v, causal_mask(S, S), cfg)
        out = flash_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True,
                              block_q=128, block_k=128, interpret=True)
        np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                                   np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("B,S,nh,ph,s,chunk", [
        (2, 64, 4, 16, 16, 16),
        (1, 128, 2, 32, 64, 32),
        (2, 96, 3, 8, 24, 32),
        (1, 64, 8, 64, 128, 16),
    ])
    def test_matches_naive_recurrence(self, B, S, nh, ph, s, chunk):
        k0 = jax.random.PRNGKey(B + S + nh)
        ks = jax.random.split(k0, 5)
        X = jax.random.normal(ks[0], (B, S, nh, ph))
        dtv = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        A = -jnp.exp(0.3 * jax.random.normal(ks[2], (nh,)))
        Bh = 0.5 * jax.random.normal(ks[3], (B, S, nh, s))
        Ch = 0.5 * jax.random.normal(ks[4], (B, S, nh, s))
        yr, fr = ssd_chunked_ref(X, dtv, A, Bh, Ch)
        yp, fp = ssd_chunked_pallas(X, dtv, A, Bh, Ch, chunk, interpret=True)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(fp), np.asarray(fr),
                                   rtol=1e-4, atol=1e-4)

    def test_model_xla_path_matches_naive(self):
        """models.ssm.ssd_chunked (the XLA default) == naive recurrence."""
        ks = jax.random.split(jax.random.PRNGKey(9), 5)
        X = jax.random.normal(ks[0], (2, 64, 4, 16))
        dtv = jax.nn.softplus(jax.random.normal(ks[1], (2, 64, 4)))
        A = -jnp.exp(0.3 * jax.random.normal(ks[2], (4,)))
        Bh = 0.5 * jax.random.normal(ks[3], (2, 64, 4, 16))
        Ch = 0.5 * jax.random.normal(ks[4], (2, 64, 4, 16))
        yr, fr = ssd_chunked_ref(X, dtv, A, Bh, Ch)
        yx, fx = ssd_chunked(X, dtv, A, Bh, Ch, 16)
        np.testing.assert_allclose(np.asarray(yx), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)

    def test_init_state_continuation(self):
        """Splitting a sequence across two calls with state carry == one call
        (prefill-continuation correctness)."""
        ks = jax.random.split(jax.random.PRNGKey(4), 5)
        B, S, nh, ph, s, chunk = 1, 64, 2, 8, 16, 16
        X = jax.random.normal(ks[0], (B, S, nh, ph))
        dtv = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        A = -jnp.exp(0.3 * jax.random.normal(ks[2], (nh,)))
        Bh = 0.5 * jax.random.normal(ks[3], (B, S, nh, s))
        Ch = 0.5 * jax.random.normal(ks[4], (B, S, nh, s))
        y_all, f_all = ssd_chunked_pallas(X, dtv, A, Bh, Ch, chunk,
                                          interpret=True)
        h = S // 2
        y1, f1 = ssd_chunked_pallas(X[:, :h], dtv[:, :h], A, Bh[:, :h],
                                    Ch[:, :h], chunk, interpret=True)
        y2, f2 = ssd_chunked_pallas(X[:, h:], dtv[:, h:], A, Bh[:, h:],
                                    Ch[:, h:], chunk, init_state=f1,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, h:]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(f2), np.asarray(f_all),
                                   rtol=1e-4, atol=1e-4)
