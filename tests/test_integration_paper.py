"""Paper-claim integration tests: the qualitative Sec. VII results at
reduced horizons (the full-horizon numbers live in benchmarks/ and
EXPERIMENTS.md)."""
import numpy as np
import pytest

from repro.core.simulator import FederatedSim, SimConfig


def run(policy, **kw):
    base = dict(horizon_s=3600, n_users=25, seed=0)
    base.update(kw)
    return FederatedSim(SimConfig(policy=policy, **base)).run()


class TestFig4:
    def test_online_saves_majority_energy_vs_immediate(self):
        """Fig. 4a headline: online saves >50% vs immediate at 1 h horizon
        (>60% at the paper's full 3 h — see benchmarks)."""
        ri, ro = run("immediate"), run("online")
        assert 1 - ro.energy_j / ri.energy_j > 0.50

    def test_online_within_15pct_of_offline(self):
        """Fig. 4a: online stabilizes within ~1.14x of the offline oracle."""
        roff, ron = run("offline"), run("online")
        assert ron.energy_j / roff.energy_j < 1.15

    def test_h_grows_with_v_beyond_knee(self):
        """Fig. 4c / Thm. 1: virtual queue grows ~linearly for V > 1e4."""
        hs = [run("online", V=V).mean_H for V in (1e3, 1e4, 1e5)]
        assert hs[0] <= hs[1] <= hs[2]
        assert hs[2] > 10 * max(hs[1], 1e-6)


class TestFig6:
    def test_energy_increases_with_arrival_rate(self):
        es = [run("online", app_arrival_p=p, horizon_s=2000).energy_j
              for p in (1e-4, 1e-2, 0.2)]
        assert es[0] < es[2]

    def test_online_converges_to_immediate_at_saturation(self):
        """High arrival rate: co-running is always available, online's
        advantage shrinks (Fig. 6a)."""
        gap_scarce = 1 - (run("online", app_arrival_p=1e-4).energy_j /
                          run("immediate", app_arrival_p=1e-4).energy_j)
        # per-update energy advantage at saturation
        ro = run("online", app_arrival_p=0.2)
        ri = run("immediate", app_arrival_p=0.2)
        assert ro.corun_fraction > 0.9   # co-run saturated
        assert gap_scarce > 0.4


class TestSyncVsAsync:
    def test_async_makes_more_global_updates(self):
        """The async schemes advance the global model far more often than
        lock-step FedAvg rounds (the paper's convergence-speed mechanism)."""
        ri = run("immediate")
        rs = run("sync")
        global_updates_sync = rs.updates / 25   # one aggregate per round
        assert ri.updates > 3 * global_updates_sync

    def test_sync_rounds_gated_by_stragglers(self):
        rs = run("sync")
        # rounds take at least the max co-run duration (~1000 s worst case)
        rounds = rs.updates / 25
        assert rounds <= 3600 / 200   # can't beat the fastest device alone
