"""Sharded user-axis simulation (SimConfig.n_devices): the chunked jax
scan partitioned over a 1-D ``("users",)`` device mesh must be an exact
twin of the single-device scan.

The contract under test (the tentpole acceptance criterion):

* push logs, queue traces (Q/H), update counts and per-user state are
  BIT-IDENTICAL to the plain jax engine across policies x aggregation
  rules x dynamics — scheduler scalars replicate and the policy hook
  computes fully replicated, so Alg. 2 decisions cannot drift across
  shards;
* scalar energy totals agree to float-sum reordering only (the per-user
  energy vector itself is exact);
* when ``n_users`` is not a multiple of the mesh size, the user axis
  pads to ``n_arr`` INERT rows — pad users never wait, never train,
  never push, never draw energy, and never touch the queues;
* sharded sims never alias the batched-sweep path or the unsharded
  executable cache (mesh signature + padded length key the memo).

Runs under however many devices the host exposes (2 forced host devices
on single-core boxes, 8 under the CI job's
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import vector_engine as ve
from repro.core.dynamics import MarkovChurnDynamics, resolve_dynamics
from repro.core.engine_state import (MODE_OFF, pad_state_per_user,
                                     pad_to_devices, unpad_state_per_user)
from repro.core.simulator import FederatedSim, SimConfig, n_slots
from repro.launch.mesh import make_sim_mesh

COMMON = dict(deadline=None,
              suppress_health_check=[HealthCheck.too_slow])


def _n_devices():
    import jax
    return len(jax.devices())


def _run(n_devices, n, policy="online", dynamics="none", agg="replace",
         seed=7, horizon=240, jax_chunk=64, collect=True):
    cfg = SimConfig(n_users=n, horizon_s=horizon, policy=policy,
                    engine="jax", collect_push_log=collect,
                    n_devices=n_devices, seed=seed, dynamics=dynamics,
                    aggregation=agg, jax_chunk=jax_chunk)
    sim = FederatedSim(cfg)
    return sim, sim.run()


def _log_cols(log):
    return np.stack([np.asarray(c, np.float64) for c in log.arrays()]) \
        if len(log) else np.zeros((6, 0))


def _assert_twin(s0, r0, s1, r1):
    """Sharded run (s1, r1) must be the plain jax run's exact twin."""
    a, b = _log_cols(r0.push_log), _log_cols(r1.push_log)
    assert a.shape == b.shape
    assert np.array_equal(a, b)
    assert np.array_equal(r0.trace_Q, r1.trace_Q)
    assert np.array_equal(r0.trace_H, r1.trace_H)
    assert r0.updates == r1.updates
    assert r0.mean_Q == r1.mean_Q
    # per-user state: exact, field by field (energy included — the lanes
    # never cross shards, only the scalar TOTAL re-associates)
    for f in ("mode", "cooldown", "app", "train_rem", "energy", "updates",
              "pulled_at", "idle_gap"):
        assert np.array_equal(np.asarray(getattr(s0.state, f)),
                              np.asarray(getattr(s1.state, f))), f
    np.testing.assert_allclose(r0.energy_j, r1.energy_j, rtol=1e-6)
    np.testing.assert_allclose(r0.trace_energy, r1.trace_energy,
                               rtol=1e-6)


# =====================================================================
# digest parity: the acceptance matrix
# =====================================================================
class TestShardedParity:
    @pytest.mark.parametrize("policy", ["online", "eps_greedy"])
    @pytest.mark.parametrize("agg", ["replace", "fedasync_poly"])
    @pytest.mark.parametrize("dynamics", ["none", "markov"])
    @pytest.mark.parametrize("n", [23, 24])
    def test_matrix(self, policy, agg, dynamics, n):
        """{policies} x {rules} x {dynamics} at a non-divisible and a
        divisible n: push logs / traces / per-user state bit-identical."""
        s0, r0 = _run(0, n, policy, dynamics, agg)
        s1, r1 = _run(_n_devices(), n, policy, dynamics, agg)
        _assert_twin(s0, r0, s1, r1)

    def test_x64_twin(self):
        """The f64 contract holds sharded too (one spot-check cell; the
        matrix above runs the default f32)."""
        import jax
        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        try:
            s0, r0 = _run(0, 23, dynamics="markov")
            s1, r1 = _run(_n_devices(), 23, dynamics="markov")
            _assert_twin(s0, r0, s1, r1)
        finally:
            jax.config.update("jax_enable_x64", prev)

    def test_autotuned_chunk_same_history(self):
        """jax_chunk=0 (auto-tune) must only change chunking, never the
        slot histories — sharded auto-tuned vs plain default-chunk."""
        _, r0 = _run(0, 23, jax_chunk=64)
        _, r1 = _run(_n_devices(), 23, jax_chunk=0)
        assert np.array_equal(r0.trace_Q, r1.trace_Q)
        assert np.array_equal(r0.trace_H, r1.trace_H)
        assert r0.updates == r1.updates

    def test_uneven_chunk_tail(self):
        """horizon not a multiple of jax_chunk: the padded tail chunk
        skips dead slots identically under the mesh."""
        _, r0 = _run(0, 23, horizon=250, jax_chunk=64)
        _, r1 = _run(_n_devices(), 23, horizon=250, jax_chunk=64)
        assert np.array_equal(r0.trace_Q, r1.trace_Q)
        assert np.array_equal(r0.trace_H, r1.trace_H)

    def test_single_device_mesh_degenerates(self):
        """n_devices=1 runs the plain path (no constraint ops) and still
        matches."""
        _, r0 = _run(0, 10)
        _, r1 = _run(1, 10)
        assert np.array_equal(r0.trace_Q, r1.trace_Q)
        assert r0.updates == r1.updates


# =====================================================================
# padding inertness (property tests; hypothesis or the conftest stub)
# =====================================================================
class TestPaddingInert:
    @settings(max_examples=6, **COMMON)
    @given(n=st.integers(3, 29), seed=st.integers(0, 2 ** 16),
           policy=st.sampled_from(["online", "eps_greedy"]),
           dynamics=st.sampled_from(["none", "markov"]))
    def test_pad_users_never_act(self, n, seed, policy, dynamics):
        """Whatever (n, seed, policy, dynamics): pad users must push
        nothing, draw no energy, enter no queue — equivalently, the
        sharded run IS the unsharded run after unpadding."""
        D = _n_devices()
        s0, r0 = _run(0, n, policy, dynamics, seed=seed, horizon=120)
        s1, r1 = _run(D, n, policy, dynamics, seed=seed, horizon=120)
        # unpadded state already sliced back to n by the driver
        assert np.shape(s1.state.mode)[0] == n
        users = np.asarray(r1.push_log.arrays()[1])
        assert users.size == 0 or users.max() < n
        assert np.array_equal(r0.trace_Q, r1.trace_Q)
        assert np.array_equal(r0.trace_H, r1.trace_H)
        assert np.array_equal(np.asarray(s0.state.energy),
                              np.asarray(s1.state.energy))

    @settings(max_examples=12, **COMMON)
    @given(n=st.integers(1, 10 ** 6), d=st.integers(1, 64))
    def test_pad_to_devices(self, n, d):
        n_arr = pad_to_devices(n, d)
        assert n_arr % d == 0 and n_arr >= n and n_arr - n < d

    def test_pad_state_fills(self):
        st0 = FederatedSim(SimConfig(n_users=5, horizon_s=60)).state
        padded = pad_state_per_user(st0, 8)
        assert np.shape(padded.mode)[0] == 8
        assert (np.asarray(padded.mode)[5:] == MODE_OFF).all()
        assert (np.asarray(padded.app)[5:] == -1).all()
        assert (np.asarray(padded.energy)[5:] == 0.0).all()
        back = unpad_state_per_user(padded, 5)
        for f in ("mode", "app", "energy", "cooldown"):
            assert np.array_equal(np.asarray(getattr(back, f)),
                                  np.asarray(getattr(st0, f))), f

    def test_pad_state_requires_dyn_rows(self):
        cfg = SimConfig(n_users=4, horizon_s=60, dynamics="markov")
        sim = FederatedSim(cfg)
        with pytest.raises(ValueError, match="pad_state"):
            pad_state_per_user(sim.state, 8)

    def test_markov_pad_rows_pinned_up(self):
        """The markov pad recipe: up/on forever, full battery, zero
        transition probabilities — with fill-1.0 uniform draws the chain
        can never edge, so pad users never ret/depart."""
        dyn = MarkovChurnDynamics(p_off=0.3, p_on=0.3)
        rows = dyn.pad_state(3)
        assert rows["on"].all() and rows["up"].all()
        assert (rows["battery"] == dyn.capacity).all()
        assert (rows["p_off"] == 0).all() and (rows["p_on"] == 0).all()
        assert not rows["net_bad"].any() and (rows["drops"] == 0).all()

    def test_base_dynamics_has_no_recipe(self):
        assert resolve_dynamics("none").pad_state(3) is None


# =====================================================================
# mesh construction + config validation
# =====================================================================
class TestMeshAndConfig:
    def test_make_sim_mesh_all_devices(self):
        mesh = make_sim_mesh(0)
        assert mesh.axis_names == ("users",)
        assert mesh.devices.size == _n_devices()

    def test_make_sim_mesh_clamps(self):
        assert make_sim_mesh(10 ** 6).devices.size == _n_devices()
        assert make_sim_mesh(1).devices.size == 1

    def test_make_sim_mesh_rejects_negative(self):
        with pytest.raises(ValueError):
            make_sim_mesh(-1)

    def test_offline_policy_rejected(self):
        with pytest.raises(ValueError, match="supports_shard"):
            SimConfig(n_users=8, horizon_s=60, policy="offline",
                      n_devices=2)

    def test_loop_engine_rejected(self):
        with pytest.raises(ValueError, match="n_devices"):
            SimConfig(n_users=8, horizon_s=60, engine="loop", n_devices=2)

    def test_negative_n_devices_rejected(self):
        with pytest.raises(ValueError):
            SimConfig(n_users=8, horizon_s=60, n_devices=-1)

    def test_sharded_sim_resolves_jax(self):
        sim = FederatedSim(SimConfig(n_users=8, horizon_s=60, n_devices=2))
        assert sim.resolve_engine() == "jax"

    def test_sweep_bucket_key_none_for_sharded(self):
        sim = FederatedSim(SimConfig(n_users=8, horizon_s=60, n_devices=2))
        assert ve.sweep_bucket_key(sim) is None
        sim2 = FederatedSim(SimConfig(n_users=8, horizon_s=60, jax_chunk=0))
        assert ve.sweep_bucket_key(sim2) is None


# =====================================================================
# the memory auto-tuner
# =====================================================================
class TestAutotune:
    def _sim(self, n=1000, horizon=600, collect=False):
        return FederatedSim(SimConfig(n_users=n, horizon_s=horizon,
                                      collect_push_log=collect))

    def test_chunk_bounds(self):
        from repro.core.autotune import autotune_scan_params
        tune = autotune_scan_params(self._sim(), n_devices=2)
        T = n_slots(self._sim().cfg)
        assert 1 <= tune.jax_chunk <= min(16384, T)
        # pow2, unless clamped to the horizon
        assert (tune.jax_chunk & (tune.jax_chunk - 1) == 0
                or tune.jax_chunk == T)

    def test_capacity_scales_with_budget(self):
        from repro.core.autotune import autotune_scan_params
        small = autotune_scan_params(self._sim(collect=True), n_devices=1,
                                     mem_bytes=64 << 20)
        big = autotune_scan_params(self._sim(collect=True), n_devices=1,
                                   mem_bytes=8 << 30)
        assert small.jax_chunk <= big.jax_chunk
        assert small.device_budget == 64 << 20
        for t in (small, big):
            assert t.push_capacity >= 1024
            assert t.push_capacity & (t.push_capacity - 1) == 0

    def test_estimate_monotonic(self):
        from repro.core.autotune import estimate_device_bytes
        lo = estimate_device_bytes(10 ** 5, 600, 256, 4096, n_devices=8)
        hi = estimate_device_bytes(10 ** 6, 600, 256, 4096, n_devices=8)
        assert hi > lo > 0
        # more devices -> smaller per-device footprint
        one = estimate_device_bytes(10 ** 6, 600, 256, 0, n_devices=1)
        eight = estimate_device_bytes(10 ** 6, 600, 256, 0, n_devices=8)
        assert eight < one

    def test_budget_positive(self):
        from repro.core.autotune import device_memory_budget
        assert device_memory_budget(1) > 0
        assert device_memory_budget(8) > 0


# =====================================================================
# executable cache: sharded and unsharded never alias
# =====================================================================
class TestShardedCache:
    def test_mesh_key_distinguishes(self):
        assert ve._mesh_key(None) is None
        k1 = ve._mesh_key(make_sim_mesh(1))
        kd = ve._mesh_key(make_sim_mesh(0))
        assert k1[0] == ("users",)
        if _n_devices() > 1:
            assert k1 != kd

    def test_no_alias_with_unsharded(self):
        from repro.core.policies import resolve_policy
        pol = resolve_policy("online")
        s0 = ve.jax_cache_stats()
        f_plain = ve._jax_chunk_fn(8, 16, 32, pol, False, False, 0)
        f_mesh = ve._jax_chunk_fn(8, 16, 32, pol, False, False, 0,
                                  mesh=make_sim_mesh(1), n_arr=8)
        assert f_plain is not f_mesh
        assert ve._jax_chunk_fn(8, 16, 32, pol, False, False, 0) is f_plain
        s1 = ve.jax_cache_stats()
        assert s1["misses"] - s0["misses"] == 2
        assert s1["hits"] - s0["hits"] >= 1

    def test_sharded_batch_rejected(self):
        from repro.core.policies import resolve_policy
        with pytest.raises(ValueError, match="never batch"):
            ve._build_jax_chunk_fn(8, 16, 32, resolve_policy("online"),
                                   False, False, 0, batch=4,
                                   mesh=make_sim_mesh(1), n_arr=8)
