"""Checkpoint/restart: roundtrip, atomicity, keep-N, cross-mesh restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (Checkpointer, latest_step_dir,
                                           restore_pytree, save_pytree)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    return {"params": {"w": jax.random.normal(ks[0], (8, 4)),
                       "b": jnp.zeros((4,), jnp.bfloat16)},
            "opt": {"mu": jax.random.normal(ks[1], (8, 4))},
            "step": jnp.int32(17)}


class TestSaveRestore:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        save_pytree(t, str(tmp_path), 5)
        restored, step = restore_pytree(_tree(seed=9), str(tmp_path))
        assert step == 5
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype

    def test_latest_step_selected(self, tmp_path):
        save_pytree(_tree(0), str(tmp_path), 1)
        save_pytree(_tree(1), str(tmp_path), 2)
        _, step = restore_pytree(_tree(), str(tmp_path))
        assert step == 2

    def test_specific_step(self, tmp_path):
        save_pytree(_tree(0), str(tmp_path), 1)
        save_pytree(_tree(1), str(tmp_path), 2)
        r, step = restore_pytree(_tree(), str(tmp_path), step=1)
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(r["params"]["w"]), np.asarray(_tree(0)["params"]["w"]))

    def test_shape_mismatch_raises(self, tmp_path):
        save_pytree(_tree(), str(tmp_path), 1)
        bad = _tree()
        bad["params"]["w"] = jnp.zeros((3, 3))
        with pytest.raises(ValueError, match="shape mismatch"):
            restore_pytree(bad, str(tmp_path))

    def test_no_tmp_dirs_left(self, tmp_path):
        save_pytree(_tree(), str(tmp_path), 1)
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]

    def test_missing_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            restore_pytree(_tree(), str(tmp_path / "nope"))


class TestCheckpointer:
    def test_async_save_and_gc(self, tmp_path):
        c = Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            c.save(_tree(s), s)
        c.wait()
        c._gc()
        steps = sorted(d for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert steps == ["step_00000003", "step_00000004"]
        assert c.latest_step() == 4

    def test_restore_after_async(self, tmp_path):
        c = Checkpointer(str(tmp_path))
        c.save(_tree(3), 10)
        r, step = c.restore(_tree(0))
        assert step == 10
        np.testing.assert_array_equal(
            np.asarray(r["params"]["w"]), np.asarray(_tree(3)["params"]["w"]))

    def test_restore_with_shardings(self, tmp_path):
        """Elastic resume: restore with explicit (here host) shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1, 1), ("data", "model"))
        c = Checkpointer(str(tmp_path))
        t = _tree()
        c.save(t, 1)
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        r, _ = c.restore(t, shardings=sh)
        for leaf in jax.tree.leaves(r):
            assert leaf.sharding == NamedSharding(mesh, P())


class TestCrashConsistency:
    def test_interrupted_write_invisible(self, tmp_path):
        """A .tmp directory (simulated crash mid-write) is never restored."""
        save_pytree(_tree(0), str(tmp_path), 1)
        os.makedirs(tmp_path / "step_00000002.tmp")
        assert latest_step_dir(str(tmp_path)).endswith("step_00000001")
