"""Reproduce Fig. 4a: the V sweep's energy knee (paper: V ~ 4e3) and the
[O(1/V), O(V)] energy-staleness trade-off, via the Scenario API.

    PYTHONPATH=src python examples/energy_sweep.py
"""
import _bootstrap  # noqa: F401  (makes `repro` importable from a checkout)

from repro.core import Scenario, run_experiment


def main():
    base = dict(horizon_s=3600, n_users=25, seed=0)
    imm = run_experiment(Scenario(policy="immediate", **base))
    off = run_experiment(Scenario(policy="offline", **base))
    print(f"immediate: {imm.energy_j / 1e3:8.1f} kJ (ceiling)")
    print(f"offline:   {off.energy_j / 1e3:8.1f} kJ (oracle floor)\n")
    print("     V    energy(kJ)   meanQ    meanH   saving_vs_immediate")
    for V in (1e2, 3e2, 1e3, 4e3, 1e4, 1e5):
        r = run_experiment(Scenario(policy="online", V=V, **base))
        print(f"{V:8.0f}  {r.energy_j / 1e3:9.1f}  {r.mean_Q:7.1f}  "
              f"{r.mean_H:7.1f}   {100 * (1 - r.energy_j / imm.energy_j):5.1f}%")
    print("\nexpected: energy falls ~1/V then flattens past the knee, while "
          "Q/H grow ~linearly in V (paper Fig. 4, Thm. 1)")


if __name__ == "__main__":
    main()
