"""Datacenter tier: async federated LM training across DP islands.

Each island = one pod slice running the sharded momentum-SGD train step;
the Lyapunov controller gates islands on low-price windows; pushes land on
the async parameter server with optional top-k compression and gap-aware
staleness dampening. Checkpoints + elastic membership come from the same
substrate the production launcher uses.

    PYTHONPATH=src python examples/federated_lm.py --arch qwen3-0.6b
"""
import argparse
import _bootstrap  # noqa: F401  (makes `repro` importable from a checkout)

from repro.configs import get_smoke_config
from repro.launch.train import IslandConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--slots", type=int, default=400)
    ap.add_argument("--compress", type=float, default=0.05,
                    help="top-k ratio for push compression (0 = off)")
    ap.add_argument("--aggregation", default="gap_aware",
                    choices=["replace", "fedasync_poly", "gap_aware"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_fedlm_ckpt")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    icfg = IslandConfig(n_islands=args.islands, slots=args.slots,
                        compress_ratio=args.compress,
                        aggregation=args.aggregation,
                        ckpt_dir=args.ckpt_dir)
    out = run(cfg, icfg)
    print(f"\nfinal eval loss: {out['final_loss']:.4f}")
    print(f"island energy:   {out['energy_j'] / 1e3:.2f} kJ")
    print(f"global updates:  {out['updates']}")
    print(f"checkpoints in:  {args.ckpt_dir}")


if __name__ == "__main__":
    main()
