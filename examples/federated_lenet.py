"""End-to-end driver: REAL federated training of LeNet-5 (the paper's own
workload) under an energy-aware schedule — scheduled local epochs of actual
JAX training, with accuracy and energy reported.

Runs through the Scenario API with the batched LeNet backend
(``ml="lenet"``), so ``--engine vectorized`` (or auto) trains whole
finisher cohorts with one vmap'd epoch instead of per-user Python
callbacks; ``--engine loop`` is the per-user reference oracle.

    PYTHONPATH=src python examples/federated_lenet.py [--policy online]
    PYTHONPATH=src python examples/federated_lenet.py --users 64 \
        --engine vectorized
"""
import argparse
import time

import _bootstrap  # noqa: F401  (makes `repro` importable from a checkout)

from repro.core import Scenario

POLICIES = ("online", "immediate", "offline", "sync", "greedy")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="online", choices=POLICIES)
    ap.add_argument("--horizon", type=int, default=2400)
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "loop", "vectorized"])
    args = ap.parse_args()

    scn = Scenario(policy=args.policy, ml="lenet",
                   ml_kwargs=dict(n_train=4000, n_test=1000),
                   horizon_s=args.horizon, n_users=args.users,
                   app_arrival_p=0.004, seed=0, engine=args.engine)
    sim = scn.build()
    t0 = time.time()
    r = sim.run()
    print(f"\npolicy={args.policy}  engine={sim.resolve_engine()}  "
          f"wall={time.time() - t0:.0f}s")
    print(f"energy: {r.energy_j / 1e3:.1f} kJ   updates: {r.updates}   "
          f"co-run fraction: {r.corun_fraction:.2f}")
    print("accuracy trace (sim-time s, test acc):")
    for t, a in r.accuracy:
        print(f"  {t:6d}  {a:.3f}")


if __name__ == "__main__":
    main()
