"""End-to-end driver: REAL federated training of LeNet-5 (the paper's own
workload) under the online energy-aware schedule — a few hundred scheduled
local epochs of actual JAX training, with accuracy and energy reported.

    PYTHONPATH=src python examples/federated_lenet.py [--policy online]
"""
import argparse
import time

import _bootstrap  # noqa: F401  (makes `repro` importable from a checkout)

from repro.core.realml import make_ml_hooks
from repro.core.simulator import FederatedSim, SimConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="online",
                    choices=["online", "immediate", "offline", "sync"])
    ap.add_argument("--horizon", type=int, default=2400)
    ap.add_argument("--users", type=int, default=8)
    args = ap.parse_args()

    hooks, state = make_ml_hooks(args.users, sync=(args.policy == "sync"),
                                 n_train=4000, n_test=1000)
    cfg = SimConfig(policy=args.policy, horizon_s=args.horizon,
                    n_users=args.users, ml_mode="real",
                    app_arrival_p=0.004, seed=0)
    t0 = time.time()
    r = FederatedSim(cfg, ml_hooks=hooks).run()
    print(f"\npolicy={args.policy}  wall={time.time() - t0:.0f}s")
    print(f"energy: {r.energy_j / 1e3:.1f} kJ   updates: {r.updates}   "
          f"co-run fraction: {r.corun_fraction:.2f}")
    print("accuracy trace (sim-time s, test acc):")
    for t, a in r.accuracy:
        print(f"  {t:6d}  {a:.3f}")


if __name__ == "__main__":
    main()
