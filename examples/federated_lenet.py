"""End-to-end driver: REAL federated training of LeNet-5 (the paper's own
workload) under an energy-aware schedule — scheduled local epochs of actual
JAX training, with accuracy and energy reported.

Runs through the Scenario API with the batched LeNet backend
(``ml="lenet"``), so ``--engine vectorized`` (or auto) trains whole
finisher cohorts with one vmap'd epoch instead of per-user Python
callbacks; ``--engine loop`` is the per-user reference oracle.

    PYTHONPATH=src python examples/federated_lenet.py [--policy online]
    PYTHONPATH=src python examples/federated_lenet.py --users 64 \
        --engine vectorized
"""
import argparse
import time

import _bootstrap  # noqa: F401  (makes `repro` importable from a checkout)

from repro.core import Scenario

POLICIES = ("online", "immediate", "offline", "sync", "greedy")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="online", choices=POLICIES)
    ap.add_argument("--horizon", type=int, default=2400)
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "loop", "vectorized"])
    ap.add_argument("--aggregation", default="replace",
                    choices=["replace", "fedasync_poly", "gap_aware",
                             "hetero_aware"],
                    help="how the server applies pushes "
                         "(core/aggregation.py); weighted rules mix "
                         "inside the fused train+push scan")
    ap.add_argument("--n-train", type=int, default=4000,
                    help="training-set size (CI smoke uses a tiny one)")
    ap.add_argument("--n-test", type=int, default=1000)
    args = ap.parse_args()

    scn = Scenario(policy=args.policy, ml="lenet",
                   ml_kwargs=dict(n_train=args.n_train, n_test=args.n_test),
                   horizon_s=args.horizon, n_users=args.users,
                   aggregation=args.aggregation,
                   app_arrival_p=0.004, seed=0, engine=args.engine)
    sim = scn.build()
    t0 = time.time()
    r = sim.run()
    print(f"\npolicy={args.policy}  engine={sim.resolve_engine()}  "
          f"aggregation={args.aggregation}  wall={time.time() - t0:.0f}s")
    print(f"energy: {r.energy_j / 1e3:.1f} kJ   updates: {r.updates}   "
          f"co-run fraction: {r.corun_fraction:.2f}")
    if r.push_log:
        w = [e["weight"] for e in r.push_log]
        print(f"applied push weights: mean {sum(w) / len(w):.3f}   "
              f"min {min(w):.3f}")
    print("accuracy trace (sim-time s, test acc):")
    for t, a in r.accuracy:
        print(f"  {t:6d}  {a:.3f}")


if __name__ == "__main__":
    main()
