"""Quickstart: the paper's system in one minute.

25 battery-powered clients (Table II device catalog), Bernoulli app
arrivals, and the four schedulers — energy + staleness side by side —
composed through the Scenario API (registry policies; swap in custom
policies/arrivals/fleets without touching engine code).

    PYTHONPATH=src python examples/quickstart.py
"""
import _bootstrap  # noqa: F401  (makes `repro` importable from a checkout)

from repro.core import Scenario, run_experiment


def main():
    print("policy      energy(kJ)  updates  corun%  meanQ  meanH")
    base = dict(horizon_s=3600, n_users=25, seed=0)
    results = {}
    for pol in ("immediate", "sync", "offline", "online"):
        r = run_experiment(Scenario(policy=pol, **base))
        results[pol] = r
        print(f"{pol:10s}  {r.energy_j / 1e3:9.1f}  {r.updates:7d}  "
              f"{100 * r.corun_fraction:5.1f}  {r.mean_Q:5.1f}  {r.mean_H:5.1f}")

    on, im = results["online"], results["immediate"]
    print(f"\nonline saves {100 * (1 - on.energy_j / im.energy_j):.0f}% "
          f"energy vs immediate scheduling "
          f"(paper Fig. 4a: >60% at the V knee)")
    off = results["offline"]
    print(f"online / offline-optimal energy ratio: "
          f"{on.energy_j / off.energy_j:.2f} (paper: ~1.14)")


if __name__ == "__main__":
    main()
