"""Make ``repro`` importable when an example is run straight from a
checkout (``python examples/quickstart.py``) without the documented
``PYTHONPATH=src`` prefix.

The documented invocation stays canonical::

    PYTHONPATH=src python examples/quickstart.py

With the prefix set (or the package installed) this helper is a no-op; the
fallback resolves ``src/`` relative to this file, so it also works from any
working directory — unlike the old per-script ``sys.path.insert(0, "src")``
hack, which silently broke outside the repo root.
"""
import os
import sys


def ensure_repro_on_path() -> None:
    try:
        import repro  # noqa: F401  (already importable: PYTHONPATH / install)
        return
    except ImportError:
        pass
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), os.pardir, "src"))
    if src not in sys.path:
        sys.path.insert(0, src)


ensure_repro_on_path()
