"""Batched serving: prefill + greedy decode with a KV/SSM cache for any
assigned architecture (smoke size on CPU; the same steps lower on the
production mesh via launch.dryrun).

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-370m
"""
import argparse
import time

import _bootstrap  # noqa: F401  (makes `repro` importable from a checkout)

import numpy as np

from repro.configs import get_smoke_config
from repro.data.synthetic import synthetic_tokens
from repro.launch.serve import BatchedServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    srv = BatchedServer(cfg)
    stream = synthetic_tokens(args.batch * args.prompt_len + 1,
                              cfg.vocab_size, seed=3)
    prompts = stream[: args.batch * args.prompt_len].reshape(
        args.batch, args.prompt_len)

    t0 = time.time()
    toks = srv.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"arch={cfg.name}  batch={args.batch}  prompt={args.prompt_len}  "
          f"gen={args.gen}")
    print(f"throughput: {toks.size / dt:.1f} tok/s (host CPU, smoke config)")
    print(f"sample continuation: {toks[0][:16].tolist()}")


if __name__ == "__main__":
    main()
